"""Cross-engine differential harness: every scenario family through every
engine, bit-identical.

This is the safety net behind the engine stack: for EVERY family registered
in ``repro.sim.EXPERIMENTS`` (the paper's E1-E4 and the image-processing
study's I1-I4 — plus anything added via ``register_experiment``, which these
tests pick up automatically) and both paper processor counts, the scalar
per-instance path, the numpy lockstep engine, the ``backend="jax"`` kernels,
the fully-fused span-bucketed ``backend="fused"`` engine, the
``backend="pallas"`` split-scoring kernels (interpret mode on CPU), and the
``backend="sharded"`` shard_map SPMD engine (degenerate one-device mesh
here; the multi-device case runs in test_engine_properties via a
forced-host-device subprocess) must produce EXACTLY the same floats
(==, not approx) for:

  - H1-H4 split trajectories (the campaign sweep primitive),
  - the H4 binary search (including the fused ``lax.scan`` bisection),
  - H5/H6 fixed-latency solves over bound grids spanning infeasible through
    exhaustion.

The numpy engine is the contractual reference; the scalar path anchors it to
the readable per-instance implementation.
"""

import pytest

from repro.core import optimal_latency, period
from repro.core.batched import (batched_fixed_latency, batched_sp_bi_p,
                                batched_trajectories)
from repro.core.heuristics import (sp_bi_l, sp_bi_p, sp_mono_l,
                                   split_trajectory)
from repro.core.metrics import single_processor_mapping
from repro.sim import EXPERIMENTS, gen_instance_batch
from repro.sim.experiments import run_experiment, summarize_experiment

FAMILIES = tuple(EXPERIMENTS)
SEEDS = range(7100, 7106)
N_STAGES = 12


def _jax_backends():
    try:
        import jax  # noqa: F401
    except Exception:  # pragma: no cover - jax is baked into the image
        return ()
    return ("jax", "fused", "pallas", "sharded")


ENGINE_BACKENDS = ("numpy",) + _jax_backends()


def _same_result(a, b):
    return (a.mapping == b.mapping and a.period == b.period
            and a.latency == b.latency and a.feasible == b.feasible
            and a.splits == b.splits)


@pytest.mark.parametrize("p", [10, 100])
@pytest.mark.parametrize("exp", FAMILIES)
def test_trajectories_all_engines_identical(exp, p):
    """H1-H4 trajectories: scalar == numpy == jax == fused, exactly."""
    batch = gen_instance_batch(exp, N_STAGES, p, SEEDS)
    for code in ("H1", "H2", "H3", "H4"):
        ref = [split_trajectory(code, wl, pf) for wl, pf in batch]
        for backend in ENGINE_BACKENDS:
            got = batched_trajectories(code, batch, backend=backend)
            assert got == ref, (code, backend)


@pytest.mark.parametrize("p", [10, 100])
@pytest.mark.parametrize("exp", FAMILIES)
def test_h4_bisection_all_engines_identical(exp, p):
    """The H4 binary search — host probe loops (numpy/jax) and the fused
    single-dispatch ``lax.scan`` bisection — equals per-instance ``sp_bi_p``
    on bounds spanning infeasible through trivially feasible."""
    batch = gen_instance_batch(exp, 10, p, SEEDS)
    fracs = [0.05, 0.2, 0.4, 0.6, 0.8, 1.0]
    bounds = [period(wl, pf, single_processor_mapping(wl, pf.fastest())) * f
              for (wl, pf), f in zip(batch, fracs)]
    refs = [sp_bi_p(wl, pf, bounds[i], iters=8)
            for i, (wl, pf) in enumerate(batch)]
    for backend in ENGINE_BACKENDS:
        rs = batched_sp_bi_p(batch, bounds, iters=8, backend=backend)
        for i, ref in enumerate(refs):
            assert _same_result(rs[i], ref), (backend, i)
        # metrics-only path (what campaigns use): same floats, no mappings
        rs_m = batched_sp_bi_p(batch, bounds, iters=8, backend=backend,
                               with_mappings=False,
                               groups=list(range(len(bounds))))
        for i, ref in enumerate(refs):
            assert rs_m[i].mapping is None
            assert (rs_m[i].period, rs_m[i].latency, rs_m[i].feasible,
                    rs_m[i].splits) == (ref.period, ref.latency, ref.feasible,
                                        ref.splits), (backend, i)


@pytest.mark.parametrize("p", [10, 100])
@pytest.mark.parametrize("exp", FAMILIES)
def test_fixed_latency_all_engines_identical(exp, p):
    """H5/H6 over a bound grid spanning infeasible (below L_opt) through
    exhaustion: every engine equals per-instance ``sp_mono_l``/``sp_bi_l``."""
    batch = gen_instance_batch(exp, N_STAGES, p, SEEDS)
    mults = [0.9, 1.0, 1.2, 1.6, 2.2, 3.0]
    bounds = [optimal_latency(wl, pf) * m
              for (wl, pf), m in zip(batch, mults)]
    for code, fn in (("H5", sp_mono_l), ("H6", sp_bi_l)):
        refs = [fn(wl, pf, bounds[i]) for i, (wl, pf) in enumerate(batch)]
        for backend in ENGINE_BACKENDS:
            rs = batched_fixed_latency(code, batch, bounds, backend=backend)
            for i, ref in enumerate(refs):
                assert _same_result(rs[i], ref), (code, backend, i)


@pytest.mark.parametrize("exp", ["E2", "I1", "I3"])
def test_campaign_harness_engines_identical(exp):
    """The whole experiment harness (curves + thresholds + feasibility
    fractions) is byte-identical across engines, image families included."""
    engines = (("scalar", "batched")
               + (("fused", "sharded") if _jax_backends() else ()))
    outs = [summarize_experiment(run_experiment(exp, 8, 10, n_pairs=4,
                                                n_bounds=4, engine=e))
            for e in engines]
    for got in outs[1:]:
        assert got == outs[0], exp
