"""Checkpointing and fault tolerance: roundtrip, torn checkpoints, crash+resume."""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, CheckpointManager


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32),
                  "d": jnp.full((2, 2), 0.5, jnp.bfloat16)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer()
    tree = _tree()
    ck.save(tmp_path / "c1", tree, step=7, extras={"loss": 1.5})
    restored, manifest = ck.restore(tmp_path / "c1", tree)
    assert manifest["step"] == 7
    assert manifest["extras"]["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_torn_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    tree = _tree()
    mgr.save(3, tree)
    mgr.save(6, tree)
    # simulate a crash mid-save at step 9: directory without _COMMITTED
    torn = tmp_path / "step_00000009"
    torn.mkdir()
    (torn / "manifest.json").write_text("{}")
    restored = mgr.restore_latest(tree)
    assert restored is not None
    _, manifest = restored
    assert manifest["step"] == 6


def test_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, max_to_keep=2, async_save=False)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.steps() == [3, 4]


def test_async_save_visible_after_wait(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    mgr.save(5, _tree())
    mgr.wait()
    assert mgr.steps() == [5]


def test_crash_and_resume_training(tmp_path):
    """Simulated node failure: the loop dies mid-run; restart resumes from the
    last committed step and reaches the same final state as an uninterrupted
    run (deterministic data + optimizer)."""
    from repro.launch.train import train_loop

    kw = dict(arch="qwen3-4b", smoke=True, steps=12, batch=2, seq=32,
              ckpt_every=5, log_every=100, seed=0)
    # uninterrupted reference
    ref = train_loop(ckpt_dir=None, **kw)
    # crash at step 7 (after the step-5 checkpoint)
    with pytest.raises(RuntimeError, match="simulated failure"):
        train_loop(ckpt_dir=str(tmp_path), fail_at_step=7, **kw)
    resumed = train_loop(ckpt_dir=str(tmp_path), **kw)
    assert resumed["start_step"] == 6
    assert resumed["final_loss"] == pytest.approx(ref["final_loss"], rel=0.05)
