"""qwen3-4b [dense]: qk_norm + GQA.  [hf:Qwen/Qwen3-8B; hf]"""

from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen3-4b", family="dense",
        n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
        d_ff=9728, vocab_size=151936,
        qk_norm=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen3-4b-smoke", family="dense",
        n_layers=3, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab_size=512,
        qk_norm=True,
    )
