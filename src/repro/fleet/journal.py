"""Write-ahead journal + snapshots: the fleet controller's durability layer.

The :class:`~repro.fleet.service.ReplanService` is deterministic and RNG-free:
its entire future behavior is a function of (current state, future events).
That makes crash safety a replay problem —

  - every tick's incoming events are appended to a **write-ahead log**
    *before* any state mutates (one CRC-checked record per tick), and
  - a full **snapshot** of service state is written every ``snapshot_every``
    ticks with an atomic temp-file + rename commit
    (:func:`repro.checkpoint.atomic_write_bytes`, the same commit primitive
    under the training checkpoints — the ROADMAP's seed checkpoint stack
    wired into the planner path).

Recovery (:meth:`ReplanService.restore`) loads the newest CRC-valid snapshot
and re-applies the WAL tail through the ordinary ``tick()`` path; because
replay is the service's determinism contract, the restored controller's
``fleet_digest()`` is **bit-identical** to an uninterrupted run (asserted in
tests/test_fleet_recovery.py over every crash point of a seeded chaos trace).

Record format — one record per line, human-greppable, torn-write safe::

    <crc32 of payload, 8 lowercase hex chars> <payload JSON, no newlines>\n

A WAL record's payload is ``{"tick": t, "events": [[type, fields], ...]}``
(:func:`repro.fleet.telemetry.event_to_wire`); a snapshot file holds exactly
one record whose payload is ``{"tick": t, "state": {...}}``.  Floats survive
JSON exactly (shortest-repr round-trip), so nothing here introduces
tolerance.  A torn or corrupt record is *detected* (CRC or parse failure):
readers recover to the longest good prefix by default, or raise
:class:`JournalError` in strict mode.  On snapshot, older snapshots beyond
``keep_snapshots`` are pruned and the WAL is compacted down to the records
the *oldest retained* snapshot has not absorbed — so recovery can fall back
past a corrupt newest snapshot and still replay forward.
"""

from __future__ import annotations

import json
import os
import pathlib
import zlib
from typing import Optional, Tuple

from ..checkpoint.checkpointer import atomic_write_bytes
from .telemetry import event_to_wire  # noqa: F401  (re-exported for callers)

WAL_NAME = "wal.log"
SNAPSHOT_GLOB = "snapshot_*.json"
FORMAT_VERSION = 1


class JournalError(RuntimeError):
    """A journal record failed its CRC/parse check, or the WAL has a gap."""


def encode_record(payload) -> bytes:
    """One journal line: crc32 of the canonical JSON payload, then the JSON."""
    data = json.dumps(payload, separators=(",", ":"), sort_keys=True).encode()
    return b"%08x " % zlib.crc32(data) + data + b"\n"


def decode_record(line: bytes):
    """Inverse of :func:`encode_record`; raises :class:`JournalError` on a
    torn, truncated, or corrupt record."""
    line = line.rstrip(b"\n")
    if len(line) < 10 or line[8:9] != b" ":
        raise JournalError(f"malformed journal record ({len(line)} bytes)")
    crc_hex, data = line[:8], line[9:]
    try:
        want = int(crc_hex, 16)
    except ValueError:
        raise JournalError(f"bad CRC field {crc_hex!r}") from None
    got = zlib.crc32(data)
    if got != want:
        raise JournalError(f"CRC mismatch: record says {want:08x}, "
                           f"payload hashes to {got:08x}")
    try:
        return json.loads(data.decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise JournalError(f"unparseable journal payload: {e}") from None


class Journal:
    """One service's durability directory: ``wal.log`` plus
    ``snapshot_<tick>.json`` files.

    ``snapshot_every`` is the snapshot cadence knob (service ticks between
    full-state snapshots; it bounds the WAL replay length after a crash),
    ``keep_snapshots`` the retention depth, and ``fsync`` whether appends and
    snapshot commits are forced to stable storage (leave on anywhere a crash
    matters; tests turn it off for speed).
    """

    def __init__(self, directory, *, snapshot_every: int = 8,
                 keep_snapshots: int = 2, fsync: bool = True):
        if snapshot_every < 1:
            raise ValueError(f"snapshot_every must be >= 1, got {snapshot_every}")
        if keep_snapshots < 1:
            raise ValueError(f"keep_snapshots must be >= 1, got {keep_snapshots}")
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.snapshot_every = int(snapshot_every)
        self.keep_snapshots = int(keep_snapshots)
        self.fsync = bool(fsync)
        self._fh = None

    @property
    def wal_path(self) -> pathlib.Path:
        return self.dir / WAL_NAME

    # -- write side -----------------------------------------------------------

    def append(self, tick: int, events) -> None:
        """WAL-append one tick's events.  Called by the service *before* any
        state mutates; the record is flushed (and fsynced) before return, so
        a controller killed mid-tick can replay the tick from disk."""
        payload = {"tick": int(tick),
                   "events": [event_to_wire(e) for e in events]}
        data = encode_record(payload)
        if self._fh is None:
            self._fh = open(self.wal_path, "ab")
        self._fh.write(data)
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())

    def write_snapshot(self, tick: int, state: dict) -> None:
        """Atomically commit a full-state snapshot taken *after* processing
        ticks ``< tick``, then prune old snapshots and compact the WAL down
        to the records the snapshot has not absorbed."""
        payload = {"format": FORMAT_VERSION, "tick": int(tick), "state": state}
        atomic_write_bytes(self.dir / f"snapshot_{int(tick):08d}.json",
                           encode_record(payload), fsync=self.fsync)
        for _, path in self._snapshot_paths()[:-self.keep_snapshots]:
            path.unlink(missing_ok=True)
        # Compact against the OLDEST retained snapshot, not the newest: if
        # the newest turns out torn/corrupt, restore can fall back to an
        # older snapshot and still find its WAL tail on disk.
        retained = self._snapshot_paths()
        self._compact(retained[0][0] if retained else int(tick))

    def _compact(self, tick: int) -> None:
        """Drop WAL records already absorbed by the snapshot at ``tick``."""
        records, _ = self.read_wal()
        keep = [r for r in records if r["tick"] >= tick]
        self.close()
        atomic_write_bytes(self.wal_path,
                           b"".join(encode_record(r) for r in keep),
                           fsync=self.fsync)

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- read side ------------------------------------------------------------

    def read_wal(self, strict: bool = False) -> Tuple[list, Optional[str]]:
        """All decodable WAL records, in append order.

        Returns ``(records, error)`` where ``error`` is ``None`` for a clean
        log or a description of the first bad record (a torn tail from a
        crash mid-append, or corruption).  Reading always recovers to the
        longest good prefix; ``strict=True`` raises :class:`JournalError`
        instead of tolerating the bad record.
        """
        if not self.wal_path.exists():
            return [], None
        records: list = []
        for idx, line in enumerate(self.wal_path.read_bytes().split(b"\n")):
            if not line:
                continue
            try:
                records.append(decode_record(line))
            except JournalError as e:
                if strict:
                    raise JournalError(
                        f"{self.wal_path} record {idx}: {e}") from None
                return records, f"record {idx}: {e}"
        return records, None

    def _snapshot_paths(self) -> list:
        out = []
        for p in sorted(self.dir.glob(SNAPSHOT_GLOB)):
            try:
                out.append((int(p.stem.split("_")[1]), p))
            except (IndexError, ValueError):
                continue
        return out

    def latest_snapshot(self) -> Optional[tuple]:
        """Newest CRC-valid snapshot as ``(tick, state)``; snapshots that
        fail their check (torn by a crash, hand-corrupted) are skipped in
        favor of the next older one."""
        for _, path in reversed(self._snapshot_paths()):
            try:
                payload = decode_record(path.read_bytes())
            except JournalError:
                continue
            if payload.get("format") != FORMAT_VERSION:
                continue
            return int(payload["tick"]), payload["state"]
        return None


# ---------------------------------------------------------------------------
# State codec: exact JSON round-trip for every object in a snapshot
# ---------------------------------------------------------------------------
# All floats go through Python's shortest-repr JSON path (exact for float64,
# including the values numpy's .tolist() hands back), ints stay ints, and
# tuples are restored as tuples — so a decoded plan reprs (and therefore
# fleet_digest()s) identically to the original.

def encode_workload(wl) -> dict:
    return {"w": wl.w.tolist(), "delta": wl.delta.tolist(), "name": wl.name}


def decode_workload(d):
    from ..core import Workload
    import numpy as np

    return Workload(np.asarray(d["w"], float), np.asarray(d["delta"], float),
                    name=d["name"])


def encode_platform(pf) -> dict:
    return {"s": pf.s.tolist(), "b": float(pf.b), "name": pf.name,
            "fail": None if pf.fail is None else pf.fail.tolist()}


def decode_platform(d):
    from ..core import Platform
    import numpy as np

    return Platform(np.asarray(d["s"], float), d["b"], name=d["name"],
                    fail=None if d["fail"] is None
                    else np.asarray(d["fail"], float))


def encode_mapping(m) -> dict:
    return {"intervals": [list(iv) for iv in m.intervals],
            "alloc": list(m.alloc)}


def decode_mapping(d):
    from ..core import Mapping

    return Mapping(tuple((int(a), int(b)) for a, b in d["intervals"]),
                   tuple(int(a) for a in d["alloc"]))


def encode_plan(plan) -> Optional[dict]:
    if plan is None:
        return None
    return {"mapping": encode_mapping(plan.mapping),
            "period": plan.period, "latency": plan.latency,
            "planner": plan.planner,
            "stage_sizes": list(plan.stage_sizes),
            "max_stage_size": plan.max_stage_size,
            "padding_overhead": plan.padding_overhead,
            "groups": None if plan.groups is None
            else [list(g) for g in plan.groups]}


def decode_plan(d):
    from ..core import StagePlan

    if d is None:
        return None
    return StagePlan(decode_mapping(d["mapping"]), d["period"], d["latency"],
                     d["planner"], tuple(int(s) for s in d["stage_sizes"]),
                     int(d["max_stage_size"]), d["padding_overhead"],
                     None if d["groups"] is None
                     else tuple(tuple(int(u) for u in g)
                                for g in d["groups"]))


def encode_result(res) -> dict:
    return {"mapping": None if res.mapping is None
            else encode_mapping(res.mapping),
            "period": res.period, "latency": res.latency,
            "feasible": res.feasible, "splits": res.splits, "name": res.name}


def decode_result(d):
    from ..core.heuristics import HeuristicResult

    return HeuristicResult(
        None if d["mapping"] is None else decode_mapping(d["mapping"]),
        d["period"], d["latency"], d["feasible"], int(d["splits"]), d["name"])


def encode_monitor(mon) -> Optional[dict]:
    if mon is None:
        return None
    return {"num_stages": mon.num_stages, "alpha": mon.alpha,
            "threshold": mon.threshold,
            "ewma": None if mon.ewma is None else mon.ewma.tolist()}


def decode_monitor(d):
    from ..pipeline.replan import StragglerMonitor
    import numpy as np

    if d is None:
        return None
    mon = StragglerMonitor(int(d["num_stages"]), alpha=d["alpha"],
                           threshold=d["threshold"])
    if d["ewma"] is not None:
        mon.ewma = np.asarray(d["ewma"], float)
    return mon
