"""Roofline analysis regressions.

The chip count of every dry-run record must derive from the record's own
mesh tag (or device count) — the bug this pins down was ``analyze_record``
hardcoding ``chips = 256`` for the literal name ``"pod16x16"``, which made
every OTHER mesh's global-flops and usefulness numbers silently wrong.
Also smoke-checks :func:`roofline.analyze_kernels`, the path that puts the
Pallas split-score kernels on the roofline from real XLA cost analysis.
"""

import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "benchmarks"))

import roofline  # noqa: E402


def test_mesh_chips_derived_from_tag():
    assert roofline.mesh_chips("pod16x16") == 256
    assert roofline.mesh_chips("pod2x16x16") == 512
    assert roofline.mesh_chips("4x8") == 32
    assert roofline.mesh_chips("pod64") == 64
    # no dims in the tag: fall back to the record's device count, then 1
    assert roofline.mesh_chips("local", 8) == 8
    assert roofline.mesh_chips("", 4) == 4
    assert roofline.mesh_chips(None, None) == 1


def _record(mesh, devices=None):
    rec = {"arch": "tpu", "shape": "s", "mesh": mesh, "model_flops": 4e9,
           "hlo": {"dot_flops": 1e9, "bytes_accessed": 1e9,
                   "collective_bytes": 0.0}}
    if devices is not None:
        rec["devices"] = devices
    return rec


@pytest.mark.parametrize("mesh,devices,chips", [
    ("pod16x16", None, 256),
    ("pod4x4", None, 16),       # the hardcode would have said 256
    ("2x16x16", None, 512),
    ("local", 8, 8),
])
def test_analyze_record_chips_from_record(mesh, devices, chips):
    out = roofline.analyze_record(_record(mesh, devices))
    assert out["hlo_flops_global"] == pytest.approx(1e9 * chips)
    assert out["useful_ratio"] == pytest.approx(4e9 / (1e9 * chips))


def test_analyze_kernels_real_cost_analysis():
    """The kernel roofline rows come from XLA's cost analysis of the program
    that actually runs: nonzero flops/bytes, a positive step-time bound, and
    a real measured time for both split-score kernels."""
    pytest.importorskip("jax")
    rows = roofline.analyze_kernels(rows_a=8, n_stages=8, repeats=1)
    assert [r["shape"] for r in rows] == ["score2", "score3"]
    for r in rows:
        assert r["dominant"] != "FAILED", r
        assert r["flops"] > 0 and r["bytes"] > 0, r
        assert r["intensity"] > 0, r
        assert r["bound_s"] > 0 and r["measured_s"] > 0, r
        assert 0 < r["roofline_frac"] <= 1.0, r
