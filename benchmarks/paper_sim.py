"""Paper reproduction: the simulation study of Section 5.

Emits (to results/paper_sim/):
  - curves_<exp>_n<k>_p<P>.csv      — the trade-off curves behind Figures 2-7
  - curves_<exp>_n<k>_p<P>_ci.csv   — mean +/- 95% CI across seed banks
                                      (only with --replications R > 1)
  - table1_thresholds.csv           — the failure-threshold table (Table 1)
  - table1_thresholds_ci.csv        — its replication CIs (with --replications)
  - claims.txt                      — machine-checked qualitative claims

Default sizes are reduced for CI speed; pass --full for the paper's 50 pairs
and every (n, p) point.  --families selects the scenario-family set: "paper"
(the source paper's E1-E4), "image" (the image-processing follow-up study's
I1-I4 — JPEG encoder profile, bimodal, correlated, uniform-wide; see
``repro.sim.generators``), or "all".  --large-grid adds the follow-up
study's n in {80, 160}, p = 1000 shapes (reduced pair count, see
--large-pairs).

Engines: ``--engine batched`` (default) runs the whole study through the
stacked-instance campaign engine (one lockstep pass over all four experiment
families per (n, p) point — see ``repro.core.batched``); ``--engine fused``
compiles every lockstep loop into a single ``jax.jit`` ``lax.while_loop``
(``repro.core.fused``, O(1) host dispatches per heuristic arity with
span-bucketed candidate grids — the engine for accelerators);
``--engine scalar`` uses the per-instance reference path; ``--engine auto``
picks batched/fused per (n, p) point from the measured crossover table
(``repro.sim.experiments.auto_engine``).  All engines produce byte-identical
CSVs (the fused engine carries an FMA guard so even its floats match).
Fused-program compiles land in JAX's persistent compilation cache, so cold
starts are paid once per machine.
"""

from __future__ import annotations

import argparse
import pathlib
import time

import numpy as np

from repro.core.fused import enable_persistent_cache, fused_available
from repro.sim import FAMILY_SETS, PAPER_FAMILIES, run_experiment
from repro.sim.experiments import (N_PROCS_LARGE, N_STAGES_LARGE,
                                   _campaign_backend, _resolve_engine,
                                   run_campaign, run_replicated,
                                   summarize_experiment, summarize_replicated)

OUT = pathlib.Path(__file__).resolve().parent.parent / "results" / "paper_sim"

HEURISTICS = ("H1", "H2", "H3", "H4", "H5", "H6")


def _run_point(exps, n, p, n_pairs, n_bounds, include_h4, engine, backend,
               replications):
    """One (n, p) grid point through the selected engine; returns
    (single-bank {exp: ExperimentResult}, {exp: ReplicatedResult} or None).
    ``engine="auto"`` resolves per point from the measured crossover table
    (``repro.sim.experiments.auto_engine``)."""
    engine = _resolve_engine(engine, n, p)
    if replications > 1:
        rep, first = run_replicated(exps, n, p, n_pairs=n_pairs,
                                    replications=replications,
                                    n_bounds=n_bounds, include_h4=include_h4,
                                    engine=engine, backend=backend)
        return first, rep
    if engine == "scalar":
        return {exp: run_experiment(exp, n, p, n_pairs=n_pairs,
                                    n_bounds=n_bounds, include_h4=include_h4,
                                    engine="scalar")
                for exp in exps}, None
    return run_campaign(exps, n, p, n_pairs=n_pairs, n_bounds=n_bounds,
                        include_h4=include_h4,
                        backend=_campaign_backend(engine, backend)), None


def run(full: bool = False, out_dir: pathlib.Path = OUT,
        engine: str = "batched", backend: str = "numpy",
        replications: int = 1, large_grid: bool = False,
        large_pairs: int = 6, families: str = "paper",
        ns: tuple = None, ps: tuple = None, n_pairs: int = None,
        n_bounds: int = None) -> dict:
    """Run the study and write its CSVs.  ``families`` selects a family set
    from ``repro.sim.FAMILY_SETS`` (or pass an explicit tuple of family
    names); ``ns``/``ps``/``n_pairs``/``n_bounds`` override the grid — the
    golden-file regression test drives a tiny grid through this exact
    pipeline, so CSV schema or tie-break drift fails tier-1."""
    out_dir.mkdir(parents=True, exist_ok=True)
    exps = FAMILY_SETS[families] if isinstance(families, str) else tuple(families)
    n_pairs = n_pairs if n_pairs is not None else (50 if full else 15)
    ns = tuple(ns) if ns is not None else ((5, 10, 20, 40) if full else (5, 20))
    ps = tuple(ps) if ps is not None else (10, 100)
    nb = n_bounds if n_bounds is not None else (12 if full else 8)
    t0 = time.time()

    points = [(n, p, n_pairs, nb, full or (n <= 20))
              for n in ns for p in ps]
    if large_grid:
        points += [(n, p, large_pairs, 8, True)
                   for n in N_STAGES_LARGE for p in N_PROCS_LARGE]

    results = {}
    rep_results = {}
    for n, p, pairs, n_bounds_pt, include_h4 in points:
        camp, rep = _run_point(exps, n, p, pairs, n_bounds_pt, include_h4,
                               engine, backend, replications)
        for exp in exps:
            res = camp[exp]
            results[(exp, n, p)] = res
            (out_dir / f"curves_{exp}_n{n}_p{p}.csv").write_text(
                summarize_experiment(res))
            if rep is not None:
                rep_results[(exp, n, p)] = rep[exp]
                (out_dir / f"curves_{exp}_n{n}_p{p}_ci.csv").write_text(
                    summarize_replicated(rep[exp]))

    # Table 1: failure thresholds at p=10, straight from the campaign results
    # (mean over the same instances the curves used).
    thr = None
    if 10 in ps:
        thr = {exp: {c: {n: results[(exp, n, 10)].thresholds[c][0] for n in ns}
                     for c in HEURISTICS} for exp in exps}
        lines = ["exp,heuristic," + ",".join(f"n{n}" for n in ns)]
        for exp in exps:
            for code in HEURISTICS:
                vals = ",".join(f"{thr[exp][code][n]:.2f}" for n in ns)
                lines.append(f"{exp},{code},{vals}")
        (out_dir / "table1_thresholds.csv").write_text("\n".join(lines))

        if replications > 1:
            lines = ["exp,heuristic,"
                     + ",".join(f"n{n}_mean,n{n}_ci95" for n in ns)]
            for exp in exps:
                for code in HEURISTICS:
                    cells = []
                    for n in ns:
                        m, ci = rep_results[(exp, n, 10)].thresholds[code]
                        cells.append(f"{m:.2f},{ci:.3f}")
                    lines.append(f"{exp},{code}," + ",".join(cells))
            (out_dir / "table1_thresholds_ci.csv").write_text("\n".join(lines))

    claims = _check_claims(exps, ns, ps, results, thr)
    (out_dir / "claims.txt").write_text("\n".join(claims))
    return {"claims": claims, "elapsed_s": round(time.time() - t0, 1),
            "points": len(results), "engine": engine,
            "replications": replications}


def _check_claims(exps, ns, ps, results, thr) -> list:
    """Machine-checked qualitative claims.  Structural claims (H5/H6
    threshold coincidence, p-scaling) apply to EVERY scenario family; the
    paper's comparative observations (H1-vs-H2 thresholds, the bi-criteria
    advantage) are claimed over its own E1-E4 families only — the image
    families have different comm/comp structure and make no such promise."""
    claims = []

    def claim(name, ok):
        claims.append(f"[{'PASS' if ok else 'FAIL'}] {name}")
        return ok

    paper_exps = [e for e in exps if e in PAPER_FAMILIES]

    # 1. H5 and H6 have identical failure thresholds (both fail exactly when
    #    L_fix < optimal latency) — structural, any family.
    if thr is not None:
        ok1 = all(abs(thr[e]["H5"][n] - thr[e]["H6"][n]) < 1e-9
                  for e in exps for n in ns)
        claim("H5/H6 failure thresholds coincide (= optimal latency)", ok1)

    # 2. 'Sp mono P has the smallest failure thresholds' among fixed-period
    #    heuristics H1-H3 (greedy 2-way splitting reaches the lowest period).
    #    2% tolerance absorbs finite-sample noise on near-ties.
    if thr is not None and paper_exps:
        ok2 = all(thr[e]["H1"][n] <= thr[e]["H2"][n] * 1.02
                  for e in paper_exps for n in ns)
        claim("H1 (Sp mono P) threshold <= H2 (3-Explo mono) [2% tol]", ok2)

    # 3. p=100 dominates p=10: periods drop with more procs — any family.
    if 10 in ps and 100 in ps:
        ok3 = True
        for exp in exps:
            for n in ns:
                if (exp, n, 10) in results and (exp, n, 100) in results:
                    m10 = results[(exp, n, 10)].curves["H5"][0]
                    m100 = results[(exp, n, 100)].curves["H5"][0]
                    sel = ~(np.isnan(m10) | np.isnan(m100))
                    if sel.any() and not (m100[sel] <= m10[sel] + 1e-6).all():
                        ok3 = False
        claim("periods improve from p=10 to p=100 (Section 5.2.2)", ok3)

    # 4. Bi-criteria H6 improves vs mono H5 more at p=100 than p=10
    #    ('bi-criteria heuristics much more performant' with many procs).
    if paper_exps and 10 in ps and 100 in ps:
        gains = {p: [] for p in ps}
        for exp in paper_exps:
            for n in ns:
                for p in ps:
                    if (exp, n, p) in results:
                        m5 = results[(exp, n, p)].curves["H5"][0]
                        m6 = results[(exp, n, p)].curves["H6"][0]
                        sel = ~(np.isnan(m5) | np.isnan(m6)) & (m5 > 0)
                        if sel.any():
                            gains[p].append(float(np.mean(1 - m6[sel] / m5[sel])))
        ok4 = (np.mean(gains.get(100, [0]))
               >= np.mean(gains.get(10, [0])) - 0.01)
        claim("bi-criteria advantage grows with processor count", ok4)

    return claims


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--engine",
                    choices=("batched", "fused", "sharded", "scalar", "auto"),
                    default="batched",
                    help="campaign engine; 'auto' picks scalar/batched/fused "
                         "per (n, p) from the measured crossover table "
                         "(README: engine selection)")
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                    help="array backend for the batched engine's scoring "
                         "kernels (ignored by --engine fused, which is "
                         "always fully traced)")
    ap.add_argument("--families", choices=tuple(FAMILY_SETS), default="paper",
                    help="scenario-family set: the source paper's E1-E4 "
                         "('paper'), the image-processing follow-up study's "
                         "I1-I4 ('image'), or both ('all')")
    ap.add_argument("--replications", type=int, default=1, metavar="R",
                    help="run each grid point over R disjoint seed banks and "
                         "emit mean +/- 95%% CI CSVs next to the point CSVs")
    ap.add_argument("--large-grid", action="store_true",
                    help="add the n in {80, 160}, p = 1000 follow-up "
                         "families (reduced pair count)")
    ap.add_argument("--large-pairs", type=int, default=6,
                    help="instance pairs per large-grid point (default 6)")
    args = ap.parse_args()
    if fused_available():
        # CLI runs amortize fused compiles across processes; library callers
        # of run() (e.g. the golden-file tests) stay side-effect-free
        enable_persistent_cache()
    out = run(full=args.full, engine=args.engine, backend=args.backend,
              replications=args.replications, large_grid=args.large_grid,
              large_pairs=args.large_pairs, families=args.families)
    for c in out["claims"]:
        print(c)
    extra = (f", {out['replications']} replications"
             if out["replications"] > 1 else "")
    print(f"paper_sim[{out['engine']}, {args.families}]: {out['points']} "
          f"experiment points in {out['elapsed_s']}s{extra}")


if __name__ == "__main__":
    main()
