"""Planning API: PlanRequest -> PlanReport over the solver registry.

The paper's portfolio of bi-criteria algorithms (heuristics H1-H6, DP
baselines, exact solvers) is exposed through a single request/report
protocol:

    report = plan_request(PlanRequest(workload, platform, Objective("period")))
    report.plan          # chosen StagePlan, ready for the runtime
    report.candidates    # full provenance: every applicable solver's
                         # (period, latency, feasible, wall_time)
    report.pareto        # non-dominated (period, latency) points

Solvers come from :mod:`repro.core.solvers` and are filtered per request by
capability metadata (objective direction, size budgets, group support) plus
explicit include/exclude lists.  Candidate metrics are evaluated in one
vectorized batch (:func:`repro.core.metrics.evaluate_batch`).  Selection is a
pluggable policy (``@register_selection``); the default ``"lexicographic"``
policy reproduces the historical ``plan()`` behavior, which remains as a thin
facade.  ``plan_pareto`` sweeps bounded solvers over bound grids and reports
the achieved Pareto front with a knee-point default selection.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional

import numpy as np

from .exact import exact_min_latency, exact_min_period
from .heuristics import (FIXED_LATENCY_HEURISTICS, FIXED_PERIOD_HEURISTICS,
                         run_heuristic)
from .metrics import Mapping, evaluate, evaluate_batch
from .pareto import (default_latency_grid, default_period_grid, pareto_front)
from .platform import Platform
from .solvers import (Candidate, applicable, get_solver, meets_bound,
                      normalize_output, registered_solvers)
from .workload import Workload


@dataclasses.dataclass(frozen=True)
class Objective:
    """Bi-criteria objective: minimize ``minimize`` subject to the other
    criterion being <= ``bound`` (bound=None -> unconstrained)."""

    minimize: str                 # "latency" | "period"
    bound: Optional[float] = None

    def __post_init__(self):
        if self.minimize not in ("latency", "period"):
            raise ValueError(self.minimize)


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """A planned pipeline mapping, ready for the runtime."""

    mapping: Mapping
    period: float
    latency: float
    planner: str                  # which algorithm produced it
    # Runtime realization data:
    stage_sizes: tuple            # layers per stage, chain order
    max_stage_size: int           # padded stage depth for the stacked runtime
    padding_overhead: float       # wasted fraction of padded compute slots
    # Deal/replication extension: processor group per interval.  None for the
    # common single-processor-per-interval plans; when set, period/latency
    # above are the *grouped* metrics and alloc holds each group's leader.
    groups: Optional[tuple] = None

    @property
    def num_stages(self) -> int:
        return len(self.stage_sizes)


class InfeasiblePlan(RuntimeError):
    pass


def _realize(mapping: Mapping, per: float, lat: float, name: str,
             groups: Optional[tuple] = None) -> StagePlan:
    sizes = tuple(e - d + 1 for d, e in mapping.intervals)
    mx = max(sizes)
    total_slots = mx * len(sizes)
    pad = 1.0 - sum(sizes) / total_slots
    return StagePlan(mapping, per, lat, name, sizes, mx, pad, groups)


# ---------------------------------------------------------------------------
# Request / report
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlanRequest:
    """What to plan: the instance, one or more objectives, solver filters,
    and budgets.

    - ``objectives``: tuple of :class:`Objective` (a bare Objective is
      accepted).  The first is primary; every bound is enforced at selection.
    - ``include``: explicit solver-name allowlist (overrides the specs'
      ``auto`` flag); ``exclude`` removes names from whatever is selected.
    - ``exact_max_p``: size budget for exponential solvers (caps their
      ``max_p``).
    - ``time_budget``: wall-clock seconds; solvers past the deadline are
      recorded as skipped candidates instead of running.
    - ``allow_groups``: admit solvers that replicate intervals over processor
      groups (the deal extension).
    - ``selection``: policy name from :data:`SELECTION_POLICIES` or a callable
      ``(candidates, request) -> Optional[Candidate]``.
    """

    workload: Workload
    platform: Platform
    objectives: tuple
    include: Optional[tuple] = None
    exclude: tuple = ()
    exact_max_p: int = 12
    time_budget: Optional[float] = None
    allow_groups: bool = False
    selection: object = "lexicographic"

    def __post_init__(self):
        objs = self.objectives
        if isinstance(objs, Objective):
            objs = (objs,)
        objs = tuple(objs)
        if not objs:
            raise ValueError("PlanRequest needs at least one objective")
        object.__setattr__(self, "objectives", objs)
        if self.include is not None:
            object.__setattr__(self, "include", tuple(self.include))
            for nm in self.include:
                get_solver(nm)
        object.__setattr__(self, "exclude", tuple(self.exclude))
        for nm in self.exclude:
            get_solver(nm)
        if not callable(self.selection) and self.selection not in SELECTION_POLICIES:
            raise KeyError(f"unknown selection policy {self.selection!r}; "
                           f"registered: {sorted(SELECTION_POLICIES)}")

    @property
    def objective(self) -> Objective:
        """The primary objective."""
        return self.objectives[0]

    def solver_specs(self, objective: Objective) -> list:
        """Applicable solvers for ``objective``, in registration order."""
        out = []
        for spec in registered_solvers():
            if self.include is not None:
                if spec.name not in self.include:
                    continue
            elif not spec.auto:
                continue
            if spec.name in self.exclude:
                continue
            if not applicable(spec, self.workload, self.platform, objective,
                              exact_max_p=self.exact_max_p,
                              allow_groups=self.allow_groups):
                continue
            out.append(spec)
        return out


@dataclasses.dataclass(frozen=True)
class PlanReport:
    """The full outcome of a plan request: the chosen plan, the candidate
    provenance table, and the achieved Pareto front."""

    request: PlanRequest
    plan: Optional[StagePlan]      # None when nothing feasible was found
    chosen: Optional[Candidate]
    candidates: tuple              # tuple[Candidate, ...], run order
    pareto: tuple                  # non-dominated feasible (period, latency)
    wall_time: float

    @property
    def feasible(self) -> bool:
        return self.plan is not None

    def best(self, objective: Optional[Objective] = None) -> Optional[Candidate]:
        """Best candidate for ``objective`` (default: the primary one) under
        the lexicographic rule."""
        objective = objective or self.request.objective
        req = dataclasses.replace(self.request, objectives=(objective,))
        return select_lexicographic(list(self.candidates), req)

    def summary(self) -> str:
        """Human-readable provenance table."""
        lines = [f"{'solver':<18} {'objective':<22} {'period':>12} {'latency':>12} "
                 f"{'feasible':>8} {'wall_ms':>8}"]
        for c in self.candidates:
            obj = c.objective.minimize + (
                "" if c.objective.bound is None else f"|bound={c.objective.bound:.4g}")
            per = f"{c.period:.6g}" if math.isfinite(c.period) else "-"
            lat = f"{c.latency:.6g}" if math.isfinite(c.latency) else "-"
            mark = " <== chosen" if self.chosen is c else (
                f"  ({c.error})" if c.error else "")
            lines.append(f"{c.solver:<18} {obj:<22} {per:>12} {lat:>12} "
                         f"{str(c.feasible):>8} {c.wall_time*1e3:>8.2f}{mark}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Selection policies (pluggable)
# ---------------------------------------------------------------------------

SELECTION_POLICIES: "dict[str, Callable]" = {}


def register_selection(name: str) -> Callable:
    """Decorator: register a selection policy ``(candidates, request) ->
    Optional[Candidate]`` under ``name``."""
    def deco(fn: Callable) -> Callable:
        SELECTION_POLICIES[name] = fn
        return fn
    return deco


def _admissible(c: Candidate, request: PlanRequest) -> bool:
    return c.mapping is not None and all(
        meets_bound(o, c.period, c.latency) for o in request.objectives)


@register_selection("lexicographic")
def select_lexicographic(candidates, request) -> Optional[Candidate]:
    """Minimize the primary criterion, tie-break on the other, then on solver
    run order — the historical ``plan(mode="auto")`` rule.  Every objective's
    bound is enforced."""
    primary = request.objective
    best, best_key = None, None
    for c in candidates:
        if not _admissible(c, request):
            continue
        key = ((c.latency, c.period) if primary.minimize == "latency"
               else (c.period, c.latency))
        if best_key is None or key < best_key:
            best, best_key = c, key
    return best


@register_selection("min-period")
def select_min_period(candidates, request) -> Optional[Candidate]:
    """Minimize period; the request's original bounds stay enforced."""
    req = dataclasses.replace(
        request, objectives=(Objective("period"),) + tuple(request.objectives))
    return select_lexicographic(candidates, req)


@register_selection("min-latency")
def select_min_latency(candidates, request) -> Optional[Candidate]:
    """Minimize latency; the request's original bounds stay enforced."""
    req = dataclasses.replace(
        request, objectives=(Objective("latency"),) + tuple(request.objectives))
    return select_lexicographic(candidates, req)


@register_selection("knee")
def select_knee(candidates, request) -> Optional[Candidate]:
    """Balanced trade-off: the admissible candidate closest (L2, normalized
    per criterion over the admissible set) to the ideal point."""
    feas = [c for c in candidates if _admissible(c, request)]
    if not feas:
        return None
    pers = np.array([c.period for c in feas])
    lats = np.array([c.latency for c in feas])
    pr = max(pers.max() - pers.min(), 1e-30)
    lr = max(lats.max() - lats.min(), 1e-30)
    score = np.hypot((pers - pers.min()) / pr, (lats - lats.min()) / lr)
    return feas[int(np.argmin(score))]


# ---------------------------------------------------------------------------
# Portfolio execution
# ---------------------------------------------------------------------------

def _run_jobs(workload: Workload, platform: Platform, jobs: list,
              deadline: Optional[float]) -> list:
    """Run (spec, objective) jobs, timed, then evaluate all plain-mapping
    results in one vectorized batch.  Returns the Candidate list in job
    order; a job failure or deadline miss becomes an infeasible candidate
    with its ``error`` set (portfolio runs never raise)."""
    rows = []
    for spec, obj in jobs:
        if deadline is not None and time.perf_counter() > deadline:
            rows.append((spec, obj, None, 0.0, "skipped: time budget exhausted"))
            continue
        t0 = time.perf_counter()
        try:
            sol = normalize_output(spec.fn(workload, platform, obj))
            err = None
        except Exception as ex:  # noqa: BLE001 — one member must not kill the run
            sol, err = None, f"{type(ex).__name__}: {ex}"
        rows.append((spec, obj, sol, time.perf_counter() - t0, err))

    need = [i for i, (_, _, sol, _, _) in enumerate(rows)
            if sol is not None and (sol.period is None or sol.latency is None)]
    if need:
        mets = evaluate_batch(workload, platform, [rows[i][2].mapping for i in need])
        met_at = {i: j for j, i in enumerate(need)}

    cands = []
    for i, (spec, obj, sol, wall, err) in enumerate(rows):
        if sol is None:
            cands.append(Candidate(spec.name, obj, None, math.inf, math.inf,
                                   False, wall, error=err))
            continue
        if sol.period is not None and sol.latency is not None:
            per, lat = float(sol.period), float(sol.latency)
        else:
            per, lat = (float(v) for v in mets[met_at[i]])
        cands.append(Candidate(spec.name, obj, sol.mapping, per, lat,
                               meets_bound(obj, per, lat), wall, groups=sol.groups,
                               reliability=sol.reliability))
    return cands


def _finish(request: PlanRequest, cands: list, t0: float) -> PlanReport:
    feas_pts = [c.point for c in cands if c.feasible]
    front = tuple(pareto_front(feas_pts)) if feas_pts else ()
    policy = (request.selection if callable(request.selection)
              else SELECTION_POLICIES[request.selection])
    chosen = policy(cands, request)
    plan = (_realize(chosen.mapping, chosen.period, chosen.latency, chosen.solver,
                     groups=chosen.groups)
            if chosen is not None else None)
    return PlanReport(request, plan, chosen, tuple(cands), front,
                      time.perf_counter() - t0)


def plan_request(request: PlanRequest) -> PlanReport:
    """Run the applicable solver portfolio for ``request`` and report the
    chosen plan with full per-solver provenance.  Never raises on
    infeasibility — check ``report.feasible`` (the ``plan()`` facade raises
    :class:`InfeasiblePlan` for back-compat)."""
    t0 = time.perf_counter()
    deadline = None if request.time_budget is None else t0 + request.time_budget
    jobs = [(spec, obj) for obj in request.objectives
            for spec in request.solver_specs(obj)]
    cands = _run_jobs(request.workload, request.platform, jobs, deadline)
    return _finish(request, cands, t0)


def plan_pareto(
    workload: Workload,
    platform: Platform,
    *,
    k: int = 20,
    include: Optional[tuple] = None,
    exclude: tuple = (),
    exact_max_p: int = 12,
    time_budget: Optional[float] = None,
    selection: object = "knee",
) -> PlanReport:
    """Pareto-first planning: sweep every applicable bounded solver over a
    ``k``-point bound grid (period grid for latency-minimizers, latency grid
    for period-minimizers), run unbounded solvers once per direction, and
    report the achieved (period, latency) front.  ``selection`` — a policy
    name or callable — picks the returned plan from the candidates (default:
    the knee of the trade-off)."""
    request = PlanRequest(
        workload, platform, (Objective("period"), Objective("latency")),
        include=include, exclude=exclude, exact_max_p=exact_max_p,
        time_budget=time_budget, selection=selection,
    )
    t0 = time.perf_counter()
    deadline = None if time_budget is None else t0 + time_budget
    pgrid = default_period_grid(workload, platform, k)
    lgrid = default_latency_grid(workload, platform, k)
    jobs = []
    seen = set()
    for obj in request.objectives:
        for spec in request.solver_specs(obj):
            if spec.needs_bound:
                grid = pgrid if obj.minimize == "latency" else lgrid
                jobs.extend((spec, Objective(obj.minimize, bound=float(bd)))
                            for bd in grid)
            elif spec.name not in seen:
                # direction-specific solvers appear for exactly one objective;
                # "both" solvers (e.g. single) would otherwise run twice.
                seen.add(spec.name)
                jobs.append((spec, obj))
    cands = _run_jobs(workload, platform, jobs, deadline)
    return _finish(request, cands, t0)


# ---------------------------------------------------------------------------
# Back-compat facades
# ---------------------------------------------------------------------------

# The historical plan(mode="auto") portfolio per objective direction.
AUTO_PORTFOLIO = {
    "latency": ("single", "H1", "H2", "H3", "H4"),
    "period": ("single", "H5", "H6", "dp-speed-ordered", "exact"),
}


def auto_request(workload: Workload, platform: Platform, objective: Objective,
                 exact_max_p: int = 12) -> PlanRequest:
    """The PlanRequest equivalent of the historical ``plan(mode="auto")``."""
    return PlanRequest(workload, platform, (objective,),
                       include=AUTO_PORTFOLIO[objective.minimize],
                       exact_max_p=exact_max_p)


def plan(
    workload: Workload,
    platform: Platform,
    objective: Objective,
    mode: str = "auto",
    exact_max_p: int = 12,
) -> StagePlan:
    """Compute a stage plan (thin facade over :func:`plan_request`).

    mode:
      - one of "H1".."H6": the corresponding paper heuristic (bound required);
      - "auto": portfolio — all applicable heuristics + DP baselines (+ exact
        when p is small), best feasible result wins;
      - "exact": exact solver (exponential in p; raises if p > exact_max_p).
        Routes period objectives to exact_min_period and latency objectives
        to exact_min_latency.
    """
    if mode in FIXED_PERIOD_HEURISTICS or mode in FIXED_LATENCY_HEURISTICS:
        if objective.bound is None:
            raise ValueError("paper heuristics need a bound")
        res = run_heuristic(mode, workload, platform, objective.bound)
        if not res.feasible or res.mapping is None:
            raise InfeasiblePlan(f"{mode} found no feasible mapping for {objective}")
        return _realize(res.mapping, res.period, res.latency, mode)

    if mode == "exact":
        if platform.p > exact_max_p:
            raise ValueError(f"exact solver limited to p <= {exact_max_p}")
        cap = objective.bound if objective.bound is not None else math.inf
        if objective.minimize == "period":
            mp, name = exact_min_period(workload, platform, latency_cap=cap), "exact"
        else:
            mp, name = exact_min_latency(workload, platform, period_cap=cap), "exact-latency"
        if mp is None:
            raise InfeasiblePlan("exact: infeasible")
        per, lat = evaluate(workload, platform, mp)
        return _realize(mp, per, lat, name)

    if mode != "auto":
        raise KeyError(mode)

    report = plan_request(auto_request(workload, platform, objective, exact_max_p))
    if report.plan is None:
        raise InfeasiblePlan(f"no planner produced a feasible mapping for {objective}")
    return dataclasses.replace(report.plan, planner=f"auto({report.chosen.solver})")


def replan_for_straggler(
    workload: Workload,
    platform: Platform,
    current: StagePlan,
    observed_stage_times: np.ndarray,
    slowdown_threshold: float = 1.3,
) -> tuple:
    """Straggler mitigation: compare observed per-stage step times against the
    plan's predicted cycle times; degrade the effective speed of any processor
    running slower than ``slowdown_threshold`` x predicted; re-plan.

    Returns (new_plan, degraded_platform).  This is exactly the paper's
    heterogeneous-processor scenario arising *online* on homogeneous hardware.
    """
    from .metrics import interval_cycle_times

    predicted = interval_cycle_times(workload, platform, current.mapping)
    observed = np.asarray(observed_stage_times, dtype=float)
    if observed.shape != predicted.shape:
        raise ValueError("one observation per stage required")
    pf = platform
    for j, (obs, pred) in enumerate(zip(observed, predicted)):
        if pred > 0 and obs / pred > slowdown_threshold:
            pf = pf.degrade(current.mapping.alloc[j], obs / pred)
    new = plan(workload, pf, Objective("period", bound=None), mode="auto")
    return new, pf
