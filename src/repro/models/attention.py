"""Attention: GQA, qk-norm, biases, sliding windows, KV caches.

Two execution paths:

 - ``plain_attention``  : einsum softmax attention, used for short sequences
   (< ~2k) and cross-attention.
 - ``blocked_attention``: flash-style online-softmax over a *static schedule of
   (query-block, key-block) pairs*.  Only pairs that intersect the causal /
   sliding-window band are enumerated, so the compiled HLO performs S^2/2
   FLOPs for causal attention and S*W for SWA — the same work a Pallas/TPU
   flash kernel does, which keeps the dry-run roofline honest.  Memory stays
   bounded by one (Bq x Bk) score block per step.

Decode uses a separate single-token path over a (possibly ring-buffered) KV
cache (:func:`decode_attention`).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig, abstract_mesh
from .layers import apply_rope, dense_init, rms_norm, shard

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, n_heads: Optional[int] = None,
                   n_kv: Optional[int] = None, head_dim: Optional[int] = None) -> dict:
    H = n_heads or cfg.n_heads
    K = n_kv or cfg.n_kv_heads
    hd = head_dim or cfg.head_dim
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    pdt = cfg.jparam_dtype
    p = {
        "wq": dense_init(ks[0], (d, H, hd), pdt, fan_in=d),
        "wk": dense_init(ks[1], (d, K, hd), pdt, fan_in=d),
        "wv": dense_init(ks[2], (d, K, hd), pdt, fan_in=d),
        "wo": dense_init(ks[3], (H, hd, d), pdt, fan_in=H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), pdt)
        p["bk"] = jnp.zeros((K, hd), pdt)
        p["bv"] = jnp.zeros((K, hd), pdt)
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((hd,), pdt)
        p["k_scale"] = jnp.ones((hd,), pdt)
    return p


def _project_qkv(params, x, kv_x, cfg: ModelConfig, positions, kv_positions,
                 rope: bool = True):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", kv_x, params["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", kv_x, params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_scale"], cfg.norm_eps)
        k = rms_norm(k, params["k_scale"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Plain attention (short sequences / cross attention)
# ---------------------------------------------------------------------------

def plain_attention(q, k, v, *, causal: bool, window: Optional[int],
                    q_positions=None, k_positions=None) -> jax.Array:
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    q5 = q.reshape(B, S, K, G, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", q5.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal or window is not None:
        pq = q_positions if q_positions is not None else jnp.arange(S)
        pk = k_positions if k_positions is not None else jnp.arange(T)
        mask = jnp.ones((S, T), bool)
        if causal:
            mask &= pq[:, None] >= pk[None, :]
        if window is not None:
            mask &= pq[:, None] - pk[None, :] < window
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)


# ---------------------------------------------------------------------------
# Blocked attention with a static block-pair schedule
# ---------------------------------------------------------------------------

def _block_pairs(nq: int, nk: int, bq: int, bk: int, causal: bool,
                 window: Optional[int]) -> list:
    """Static (qi, ki) schedule: only blocks intersecting the visibility band."""
    pairs = []
    for qi in range(nq):
        q_lo, q_hi = qi * bq, qi * bq + bq - 1
        for ki in range(nk):
            k_lo, k_hi = ki * bk, ki * bk + bk - 1
            if causal and k_lo > q_hi:
                continue  # entirely in the future
            if window is not None and k_hi < q_lo - window + 1:
                continue  # entirely outside the window
            pairs.append((qi, ki))
    return pairs


def _mesh_model_size() -> int:
    am = abstract_mesh()
    if am is None or am.empty or "model" not in am.axis_names:
        return 1
    return am.shape["model"]


def seq_parallel_attention(q, k, v, *, causal: bool, window: Optional[int],
                           block_q: int = 512, block_k: int = 512) -> jax.Array:
    """Sequence-parallel blocked attention (manual over 'model').

    For architectures whose head count does not divide the model axis (56, 40,
    20 heads on a 16-way axis), GSPMD falls back to head_dim sharding, which
    puts an all-reduce after EVERY score/PV block einsum of the pair scan.
    Here instead each model shard owns a contiguous q-sequence chunk, K/V are
    all-gathered once (tens of MB), and the pair scan runs entirely locally.
    Cost: the static pair schedule cannot be causally pruned per shard (the
    offset is traced), so attention does rectangle S_loc x T work — 2x the
    triangle — which is still far cheaper than per-pair collectives.
    K/V are staged through f32 around the gather: XLA:CPU crashes compiling
    bf16 collectives (AllReducePromotion pass bug)."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    msize = _mesh_model_size()
    S_loc = S // msize
    nq, nk = S_loc // block_q, T // block_k
    from jax.sharding import PartitionSpec as P

    q5 = q.reshape(B, S, K, G, hd).transpose(0, 2, 3, 1, 4)   # (B,K,G,S,hd)
    q5 = jax.lax.with_sharding_constraint(q5, P(None, None, None, "model", None))
    k32 = jax.lax.with_sharding_constraint(
        k.astype(jnp.float32), P(None, "model", None, None))
    v32 = jax.lax.with_sharding_constraint(
        v.astype(jnp.float32), P(None, "model", None, None))

    def local(q_l, k_l, v_l):
        kf = jax.lax.all_gather(k_l, "model", axis=1, tiled=True)   # (B,T,K,hd)
        vf = jax.lax.all_gather(v_l, "model", axis=1, tiled=True)
        q_off = jax.lax.axis_index("model") * S_loc
        scale = 1.0 / math.sqrt(hd)

        m0 = jnp.full((B, K, G, S_loc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, S_loc), jnp.float32)
        a0 = jnp.zeros((B, K, G, S_loc, hd), jnp.float32)

        def step(carry, idx):
            m, l, acc = carry
            qi, ki = idx // nk, idx % nk
            qs = qi * block_q
            ks = ki * block_k
            qb = jax.lax.dynamic_slice_in_dim(q_l, qs, block_q, axis=3)
            kb = jax.lax.dynamic_slice_in_dim(kf, ks, block_k, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vf, ks, block_k, axis=1)
            s_blk = jnp.einsum("bkgqh,btkh->bkgqt", qb.astype(jnp.float32),
                               kb) * scale
            pq = q_off + qs + jnp.arange(block_q)
            pk = ks + jnp.arange(block_k)
            mask = jnp.ones((block_q, block_k), bool)
            if causal:
                mask &= pq[:, None] >= pk[None, :]
            if window is not None:
                mask &= pq[:, None] - pk[None, :] < window
            s_blk = jnp.where(mask, s_blk, NEG_INF)
            m_blk = s_blk.max(axis=-1)
            p_blk = jnp.exp(s_blk - m_blk[..., None])
            l_blk = p_blk.sum(axis=-1)
            a_blk = jnp.einsum("bkgqt,btkh->bkgqh", p_blk, vb)
            m_old = jax.lax.dynamic_slice_in_dim(m, qs, block_q, axis=3)
            l_old = jax.lax.dynamic_slice_in_dim(l, qs, block_q, axis=3)
            a_old = jax.lax.dynamic_slice_in_dim(acc, qs, block_q, axis=3)
            m_new = jnp.maximum(m_old, m_blk)
            alpha = jnp.exp(m_old - m_new)
            beta = jnp.exp(m_blk - m_new)
            l_new = alpha * l_old + beta * l_blk
            a_new = alpha[..., None] * a_old + beta[..., None] * a_blk
            m = jax.lax.dynamic_update_slice_in_dim(m, m_new, qs, axis=3)
            l = jax.lax.dynamic_update_slice_in_dim(l, l_new, qs, axis=3)
            acc = jax.lax.dynamic_update_slice_in_dim(acc, a_new, qs, axis=3)
            return (m, l, acc), None

        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                      jnp.arange(nq * nk))
        l = jnp.where(l == 0.0, 1.0, l)
        return (acc / l[..., None]).astype(q_l.dtype)

    mapped = jax.shard_map(
        local,
        in_specs=(P(None, None, None, "model", None),
                  P(None, "model", None, None), P(None, "model", None, None)),
        out_specs=P(None, None, None, "model", None),
        axis_names={"model"}, check_vma=False)
    out = mapped(q5, k32, v32)                                # (B,K,G,S,hd)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)


def blocked_attention(q, k, v, *, causal: bool, window: Optional[int],
                      block_q: int = 512, block_k: int = 512) -> jax.Array:
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    if S % block_q or T % block_k:
        return plain_attention(q, k, v, causal=causal, window=window)
    msize = _mesh_model_size()
    if msize > 1 and H % msize != 0 and S == T and S % msize == 0 \
            and (S // msize) % 128 == 0:
        # head count does not divide the model axis: head/hd sharding would
        # put collectives inside the pair scan — go sequence-parallel instead
        bq = min(block_q, S // msize)
        return seq_parallel_attention(q, k, v, causal=causal, window=window,
                                      block_q=bq, block_k=block_k)
    nq, nk = S // block_q, T // block_k
    pairs = _block_pairs(nq, nk, block_q, block_k, causal, window)
    qi_arr = jnp.array([p[0] for p in pairs], jnp.int32)
    ki_arr = jnp.array([p[1] for p in pairs], jnp.int32)

    q5 = q.reshape(B, S, K, G, hd).transpose(0, 2, 3, 1, 4)   # (B,K,G,S,hd)
    q5 = shard(q5, "batch", None, None, None, None)
    k = shard(k, "batch", None, None, None)
    v = shard(v, "batch", None, None, None)
    scale = 1.0 / math.sqrt(hd)

    # Per-q-block segments (unrolled): each q block scans over its own static
    # in-band k-block list with a SMALL (bq-sized) online-softmax carry.
    # Versus one scan over all (qi, ki) pairs updating a full-S carry, this
    # removes the per-step dynamic-update-slice + carry copies of a (B,K,G,S,
    # hd) fp32 buffer — ~4 TB of HBM traffic on an 80-layer model — while
    # keeping exact causal/SWA flop pruning and static trip counts.
    pairs_by_q: dict = {}
    for qi, ki in pairs:
        pairs_by_q.setdefault(qi, []).append(ki)

    def run_qblock(qi: int, kis: list) -> jax.Array:
        qs = qi * block_q
        qb = jax.lax.slice_in_dim(q5, qs, qs + block_q, axis=3)      # (B,K,G,bq,hd)
        qb = qb.astype(jnp.float32)
        m0 = jnp.full((B, K, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, K, G, block_q, hd), jnp.float32)

        def step(carry, ki):
            m, l, acc = carry
            ks = ki * block_k
            kb = jax.lax.dynamic_slice_in_dim(k, ks, block_k, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, ks, block_k, axis=1)
            s_blk = jnp.einsum("bkgqh,btkh->bkgqt", qb,
                               kb.astype(jnp.float32)) * scale       # (B,K,G,bq,bk)
            pq = qs + jnp.arange(block_q)
            pk = ks + jnp.arange(block_k)
            mask = jnp.ones((block_q, block_k), bool)
            if causal:
                mask &= pq[:, None] >= pk[None, :]
            if window is not None:
                mask &= pq[:, None] - pk[None, :] < window
            s_blk = jnp.where(mask, s_blk, NEG_INF)
            m_blk = s_blk.max(axis=-1)
            p_blk = jnp.exp(s_blk - m_blk[..., None])
            m_new = jnp.maximum(m, m_blk)
            alpha = jnp.exp(m - m_new)
            beta = jnp.exp(m_blk - m_new)
            l = alpha * l + beta * p_blk.sum(axis=-1)
            a_blk = jnp.einsum("bkgqt,btkh->bkgqh", p_blk, vb.astype(jnp.float32))
            acc = alpha[..., None] * acc + beta[..., None] * a_blk
            return (m_new, l, acc), None

        if len(kis) == 1:
            (m, l, acc), _ = step((m0, l0, a0), jnp.int32(kis[0]))
        else:
            (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                          jnp.asarray(kis, jnp.int32))
        l = jnp.where(l == 0.0, 1.0, l)
        return acc / l[..., None]                                    # (B,K,G,bq,hd)

    outs = [run_qblock(qi, pairs_by_q[qi]) for qi in sorted(pairs_by_q)]
    out = jnp.concatenate(outs, axis=3)                              # (B,K,G,S,hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Full-sequence attention entry point (train / prefill)
# ---------------------------------------------------------------------------

def attention(params, x, cfg: ModelConfig, *, positions=None, causal=True,
              window: Optional[int] = None, kv_x=None, rope=True) -> jax.Array:
    B, S, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    T = kv_x.shape[1]
    if positions is None:
        positions = jnp.arange(S)[None, :]
    kv_positions = positions if kv_x is x else jnp.arange(T)[None, :]
    q, k, v = _project_qkv(params, x, kv_x, cfg, positions, kv_positions, rope=rope)
    if cfg.use_pallas and S > 1024 and S % 512 == 0 and T % 512 == 0:
        from ..kernels import ops as kops

        out = kops.flash_attention(q, k, v, causal=causal, window=window)
    elif S <= 2048 or S % 512 or T % 512:
        out = plain_attention(q, k, v, causal=causal, window=window)
    else:
        out = blocked_attention(q, k, v, causal=causal, window=window,
                                block_q=min(cfg.attn_chunk, 512),
                                block_k=min(cfg.attn_chunk, 512))
    dt = x.dtype
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return shard(y, "batch", "seq", "d_model")


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array           # (B, C, K, hd)  C = cache capacity (seq_len or window)
    v: jax.Array
    pos: jax.Array         # (B,) next absolute position to write
    positions: jax.Array   # (B, C) absolute position stored in each slot (-1 empty)

    @property
    def capacity(self) -> int:
        return self.k.shape[1]


def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               n_kv: Optional[int] = None, head_dim: Optional[int] = None,
               dtype=None) -> KVCache:
    K = n_kv or cfg.n_kv_heads
    hd = head_dim or cfg.head_dim
    dt = dtype or cfg.jdtype
    return KVCache(
        k=jnp.zeros((batch, capacity, K, hd), dt),
        v=jnp.zeros((batch, capacity, K, hd), dt),
        pos=jnp.zeros((batch,), jnp.int32),
        positions=jnp.full((batch, capacity), -1, jnp.int32),
    )


def cache_from_prefill(cfg: ModelConfig, k, v, window: Optional[int] = None) -> KVCache:
    """Build a cache holding full-prefill K/V (optionally only the last window)."""
    B, S = k.shape[0], k.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    if window is not None and S > window:
        k, v = k[:, -window:], v[:, -window:]
        positions = positions[:, -window:]
    return KVCache(k=k, v=v, pos=jnp.full((B,), S, jnp.int32), positions=positions)


def decode_attention_step(params, x, cache: KVCache, cfg: ModelConfig,
                          window: Optional[int] = None) -> tuple:
    """One-token attention: x (B, 1, d) against the cache; returns (out, cache)."""
    B = x.shape[0]
    dt = x.dtype
    pos = cache.pos                                            # (B,)
    q, k_new, v_new = _project_qkv(params, x, x, cfg, pos[:, None], pos[:, None])
    # slot: ring buffer when windowed, else absolute position
    C = cache.capacity
    slot = (pos % C).astype(jnp.int32)                         # (B,)
    bidx = jnp.arange(B)
    k = cache.k.at[bidx, slot].set(k_new[:, 0].astype(cache.k.dtype))
    v = cache.v.at[bidx, slot].set(v_new[:, 0].astype(cache.v.dtype))
    positions = cache.positions.at[bidx, slot].set(pos)
    k = shard(k, "batch", "seq_kv", "kv_heads", None)
    v = shard(v, "batch", "seq_kv", "kv_heads", None)

    H, hd = q.shape[2], q.shape[3]
    K = k.shape[2]
    G = H // K
    if cfg.use_pallas:
        from ..kernels import ops as kops

        out = kops.decode_attention(q[:, 0], k, v, positions, pos, window=window)
        out = out[:, None]
    else:
        q5 = q.reshape(B, 1, K, G, hd)
        scores = jnp.einsum("bskgh,btkh->bkgst", q5.astype(jnp.float32),
                            k.astype(jnp.float32)) / math.sqrt(hd)     # (B,K,G,1,C)
        valid = (positions >= 0) & (positions <= pos[:, None])
        if window is not None:
            valid &= positions > pos[:, None] - window
        scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v)
        out = out.reshape(B, 1, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    new_cache = KVCache(k=k, v=v, pos=pos + 1, positions=positions)
    return y, new_cache
