"""Roofline analysis: dry-run artifacts + the Pallas split-score kernels.

Per (arch x shape x mesh) cell, derive the three roofline terms from the
per-device partitioned HLO (loop-aware parse, see repro.launch.hlo_analysis):

    compute    = perdev_dot_flops       / PEAK_FLOPS      (197 TF/s bf16/chip)
    memory     = perdev_bytes_accessed  / HBM_BW          (819 GB/s)
    collective = perdev_collective_bytes/ LINK_BW         (~50 GB/s/link ICI)

(dividing per-device quantities by per-chip rates is identical to the spec's
global/(chips x rate) form).  Also reported: the dominant term, the step-time
bound max(terms), MODEL_FLOPS (analytic useful flops) and the usefulness
ratio MODEL_FLOPS / HLO_FLOPs, and the roofline fraction
compute_term / max(terms) (the score: 1.0 = compute-bound at peak).

Beyond the dryrun-JSON path, :func:`analyze_kernels` puts the planner's OWN
hot kernels on the roofline: it compiles the ``pl.pallas_call`` split-score
kernels of ``repro.kernels.split_score`` at campaign-representative shapes,
reads flops / bytes-accessed from XLA's cost analysis of the program that
actually executes, times it, and reports arithmetic intensity, the roofline
step-time bound, and the achieved fraction of that bound.

Reads results/dryrun/*.json; writes results/roofline.csv and prints a table.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import re
import time

import numpy as np

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

DRYRUN = pathlib.Path(__file__).resolve().parent.parent / "results" / "dryrun"
OUT = pathlib.Path(__file__).resolve().parent.parent / "results" / "roofline.csv"


def mesh_chips(mesh, devices=None) -> int:
    """Chip count of a mesh tag: the product of its ``x``-separated dims
    (``"pod16x16"`` -> 256, ``"pod2x16x16"`` -> 512, ``"4x8"`` -> 32),
    falling back to the record's device count when the tag has no dims.
    Every mesh derives uniformly — no hardcoded per-name constants."""
    dims = re.findall(r"\d+", str(mesh or ""))
    if dims:
        chips = 1
        for d in dims:
            chips *= int(d)
        return chips
    return int(devices) if devices else 1


def analyze_record(rec: dict) -> dict:
    chips = mesh_chips(rec.get("mesh"), rec.get("devices"))
    hlo = rec["hlo"]
    compute = hlo["dot_flops"] / PEAK_FLOPS
    memory = hlo["bytes_accessed"] / HBM_BW
    collective = hlo["collective_bytes"] / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    model_flops = rec.get("model_flops", 0.0)
    hlo_flops_global = hlo["dot_flops"] * chips
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute, "memory_s": memory, "collective_s": collective,
        "dominant": dominant, "bound_s": bound,
        "roofline_frac": compute / bound if bound else 0.0,
        "model_flops": model_flops, "hlo_flops_global": hlo_flops_global,
        "useful_ratio": useful,
        "temp_gb": rec.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
        "arg_gb": rec.get("memory", {}).get("argument_size_in_bytes", 0) / 1e9,
    }


def load_all(dryrun_dir=DRYRUN) -> list:
    rows = []
    for p in sorted(pathlib.Path(dryrun_dir).glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("ok"):
            rows.append(analyze_record(rec))
        else:
            rows.append({"arch": rec.get("arch"), "shape": rec.get("shape"),
                         "mesh": rec.get("mesh"), "dominant": "FAILED",
                         "error": rec.get("error", "?")[:80]})
    return rows


def _cost_analysis(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions (dict, or a
    one-element list of dicts)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


def analyze_kernels(rows_a: int = 128, n_stages: int = 64,
                    repeats: int = 5) -> list:
    """Roofline the Pallas split-score kernels from REAL cost analysis.

    Compiles :func:`repro.kernels.split_score.score_2way_pallas` /
    ``score_3way_pallas`` at a campaign-representative shape (``rows_a``
    lockstep rows, worst-interval span ``n_stages``), reads flops and
    bytes-accessed from XLA's cost analysis of the compiled program (the one
    that actually executes — interpret-mode emulation on CPU, native on
    TPU/GPU), times it, and reports per kernel: arithmetic intensity, the
    roofline step-time bound ``max(flops/PEAK, bytes/HBM_BW)``, and the
    achieved fraction of that bound.  Returns dicts shaped like
    :func:`analyze_record` rows so they share the CSV/table.
    """
    try:
        import jax
        from repro.kernels.split_score import (pair_need, score_2way_pallas,
                                               score_3way_pallas)
    except Exception as e:  # pragma: no cover - jax is baked into the image
        return [{"arch": "kernel", "shape": "split_score", "mesh": "local",
                 "dominant": "FAILED", "error": str(e)[:80]}]
    jax.config.update("jax_enable_x64", True)
    rng = np.random.default_rng(0)
    A, n = int(rows_a), int(n_stages)
    out = []

    def measure(name, fn, args, kwargs):
        flat = lambda: jax.block_until_ready(fn(*args, **kwargs))
        flat()                                   # compile + warm
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            flat()
            times.append(time.perf_counter() - t0)
        measured = float(np.median(times))
        lowered = jax.jit(lambda *a: fn(*a, **kwargs)).lower(*args)
        cost = _cost_analysis(lowered.compile())
        flops = float(cost.get("flops", 0.0))
        byts = float(cost.get("bytes accessed", 0.0))
        compute = flops / PEAK_FLOPS
        memory = byts / HBM_BW
        bound = max(compute, memory)
        out.append({
            "arch": "kernel", "shape": name, "mesh": "local",
            "compute_s": compute, "memory_s": memory, "collective_s": 0.0,
            "dominant": "compute" if compute >= memory else "memory",
            "bound_s": bound,
            "roofline_frac": compute / bound if bound else 0.0,
            "model_flops": flops, "hlo_flops_global": flops,
            "useful_ratio": 1.0, "temp_gb": 0.0, "arg_gb": byts / 1e9,
            "flops": flops, "bytes": byts,
            "intensity": flops / byts if byts else 0.0,
            "measured_s": measured,
            "achieved_frac": bound / measured if measured else 0.0,
        })

    # 2-way: K = n - 1 candidate cuts per row, full-span need
    K2 = n - 1
    pre_C = rng.random((A, K2))
    measure("score2", score_2way_pallas,
            (rng.random((A, 1)), pre_C, rng.random((A, 1)),
             rng.random((A, 1)), rng.random((A, K2)), rng.random((A, 1)),
             1.0, rng.random((A, 1)), rng.random((A, 1))),
            {"need": np.full(A, K2)})
    # 3-way: all r1-major (c1, c2) pairs of the full span x 6 permutations
    K3 = (n - 1) * (n - 2) // 2
    measure("score3", score_3way_pallas,
            (rng.random((A, 1, 3, K3)), rng.random((A, 1, 3, K3)),
             rng.random((A, 1, 3, K3)), rng.random((A, 6, 3, 1)),
             rng.random((A, 1, 1))),
            {"need": np.asarray(pair_need(np.full(A, n), K3))})
    return out


def run() -> list:
    rows = load_all() + analyze_kernels()
    header = ("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
              "bound_s,roofline_frac,useful_ratio,temp_gb")
    lines = [header]
    out_rows = []
    for r in rows:
        if r.get("dominant") == "FAILED":
            lines.append(f"{r['arch']},{r['shape']},{r['mesh']},,,,FAILED,,,,")
            continue
        lines.append(
            f"{r['arch']},{r['shape']},{r['mesh']},{r['compute_s']:.4f},"
            f"{r['memory_s']:.4f},{r['collective_s']:.4f},{r['dominant']},"
            f"{r['bound_s']:.4f},{r['roofline_frac']:.3f},"
            f"{r['useful_ratio']:.3f},{r['temp_gb']:.2f}")
        extra = (f";int={r['intensity']:.1f};meas_us={r['measured_s'] * 1e6:.0f}"
                 if "measured_s" in r else "")
        out_rows.append((f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}", 0.0,
                         f"frac={r['roofline_frac']:.3f};dom={r['dominant']}"
                         + extra))
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text("\n".join(lines))
    return out_rows


def main() -> None:
    rows = load_all() + analyze_kernels()
    print(f"{'arch':18s} {'shape':12s} {'mesh':12s} {'comp_s':>8s} {'mem_s':>8s} "
          f"{'coll_s':>8s} {'dominant':>10s} {'frac':>6s} {'useful':>7s} {'tmpGB':>6s}")
    for r in rows:
        if r.get("dominant") == "FAILED":
            print(f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:12s} "
                  f"{'FAILED: ' + r.get('error', ''):s}")
            continue
        print(f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:12s} "
              f"{r['compute_s']:8.3f} {r['memory_s']:8.3f} {r['collective_s']:8.3f} "
              f"{r['dominant']:>10s} {r['roofline_frac']:6.3f} "
              f"{r['useful_ratio']:7.3f} {r['temp_gb']:6.1f}")
    run()
    print(f"\nwrote {OUT}")


if __name__ == "__main__":
    main()
