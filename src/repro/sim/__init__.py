"""Paper simulation study (Section 5): random instance generators E1-E4,
experiment runner (scalar / batched / fused engines), replication sweeps,
failure thresholds."""

from .generators import EXPERIMENTS, InstanceBatch, gen_instance, gen_instance_batch
from .experiments import (ReplicatedResult, failure_thresholds, run_campaign,
                          run_experiment, run_replicated, summarize_experiment,
                          summarize_replicated, trajectory)

__all__ = ["EXPERIMENTS", "InstanceBatch", "gen_instance", "gen_instance_batch",
           "ReplicatedResult", "run_experiment", "run_campaign",
           "run_replicated", "failure_thresholds", "trajectory",
           "summarize_experiment", "summarize_replicated"]
