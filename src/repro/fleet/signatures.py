"""Canonical instance signatures: exact dedup of relabeled replan problems.

Two replan requests are *the same problem* when their workloads are equal and
their platforms are equal up to a renaming of processor indices.  The paper's
heuristics touch the platform only through ``Platform.sorted_indices()`` (the
stable non-increasing-speed order) and the speed values themselves, so the
solve depends on the *sorted speed sequence*, not on which physical pod
carries which speed:

  Relabeling theorem.  Let ``perm = platform.sorted_indices()`` and let the
  canonical platform carry speeds ``s[perm]``.  Every split decision, period
  and latency the heuristics produce on the canonical platform is bit-for-bit
  the one they produce on the original, with processor ``c`` of the canonical
  solve standing for processor ``perm[c]`` of the original.  (On the
  canonical platform ``sorted_indices()`` is the identity — speeds are
  non-increasing and equal speeds sit in increasing index order — so both
  runs enroll the same speed sequence and score identical candidates.)

Hence: solve the canonical problem once, fan the result back out through each
subscriber's ``perm`` via :func:`remap_alloc`.  The signature is a blake2b
digest of the canonical problem bytes — exact equality of (n, p, b, w, delta,
sorted s), no tolerance — so a cache hit can never change a result, only
skip work.  ``span_bucket`` exposes the fused engine's power-of-two grid
bucket for the instance (grouping solves by bucket keeps batched grids
dense); tests assert the dedup path is bit-identical to solo scalar replans.
"""

from __future__ import annotations

import dataclasses
import hashlib
import struct

import numpy as np

from ..core import Platform, Workload


def span_bucket(n: int) -> int:
    """The fused engine's grid bucket: smallest power of two >= n (stage
    count == the widest interval a split can ever score)."""
    if n < 1:
        raise ValueError("need n >= 1")
    return 1 << (int(n) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class Signature:
    """Identity of a canonical replan problem.

    ``digest`` decides equality; (n, p, b) ride along because only
    same-shaped problems can be stacked into one ``ProblemBatch``, and
    ``bucket`` is the fused-grid span bucket for the instance.
    """

    digest: str
    n: int
    p: int
    b: float

    @property
    def bucket(self) -> int:
        return span_bucket(self.n)

    @property
    def shape(self) -> tuple:
        return (self.n, self.p, self.b)


def signature(workload: Workload, platform: Platform) -> Signature:
    """Canonical signature of a replan problem: hash of the exact bytes of
    (n, p, b, w, delta, speed-sorted s) — plus the speed-sorted failure
    probabilities when the platform carries them (reliability-floor replans
    depend on them; platforms without a failure model keep their exact PR-6
    digests, so existing caches and dedup behavior are unchanged)."""
    order = platform.sorted_indices()
    h = hashlib.blake2b(digest_size=16)
    h.update(struct.pack("<qqd", workload.n, platform.p, float(platform.b)))
    h.update(np.ascontiguousarray(workload.w).tobytes())
    h.update(np.ascontiguousarray(workload.delta).tobytes())
    h.update(np.ascontiguousarray(platform.s[order]).tobytes())
    if platform.fail is not None:
        h.update(b"fail")
        h.update(np.ascontiguousarray(platform.fail[order]).tobytes())
    return Signature(h.hexdigest(), workload.n, platform.p, float(platform.b))


def canonicalize(platform: Platform) -> tuple:
    """(canonical platform, perm): speeds sorted non-increasing, stable.
    ``perm[c]`` is the original index of canonical processor ``c``.  Failure
    probabilities (when present) follow their processors through the
    permutation."""
    perm = platform.sorted_indices()
    canon = Platform(platform.s[perm], platform.b, name=f"{platform.name}-canon",
                     fail=None if platform.fail is None else platform.fail[perm])
    return canon, perm


def remap_alloc(alloc, perm) -> tuple:
    """Translate a canonical-space processor allocation back to the original
    instance's indices (see the relabeling theorem above)."""
    return tuple(int(perm[a]) for a in alloc)
