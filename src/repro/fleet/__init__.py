"""Fleet replanning service: planning-as-a-service over the paper's heuristics.

The paper plans one pipeline offline.  Online, observed drift — stragglers,
preemptions, autoscale events — turns a homogeneous platform into a
different-speed one, where chains-to-chains mapping is NP-hard and the paper's
fast heuristics are the only option.  A fleet runs thousands of pipeline
instances at once, so one-off ``plan()`` calls do not scale; this subsystem
ingests a drift-event stream, dedups identical-up-to-relabeling replan
requests through canonical instance signatures, and batches the distinct
problems through the lockstep engine (:mod:`repro.core.batched`) so a tick's
worth of replans costs a few device programs instead of thousands of scalar
solves.

Modules:

  - :mod:`repro.fleet.telemetry`  — drift event types, synthetic burst-trace
    generator, deterministic trace replay
  - :mod:`repro.fleet.signatures` — canonical (n, speed-order, span-bucket)
    instance signatures + the relabeling theorem that makes dedup exact
  - :mod:`repro.fleet.service`    — the controller loop: collect, dedup,
    warm-start, batch-solve, publish
  - :mod:`repro.fleet.metrics`    — replans/sec, p50/p99 replan latency,
    dedup hit-rate, plan churn, graceful-degradation counters (the BENCH
    surface)
  - :mod:`repro.fleet.chaos`      — fault injection over telemetry traces:
    correlated pod-failure storms, flapping pods, event drop/dup/reorder,
    and controller kill/restart-from-journal
  - :mod:`repro.fleet.journal`    — write-ahead event log + CRC-checked
    atomic snapshots; the crash-recovery substrate under
    ``ReplanService.restore``
  - :mod:`repro.fleet.supervision` — the controller/worker split: supervised
    solve workers with heartbeats, timeouts, backoff retries, and restarts
  - :mod:`repro.fleet.transport`  — CRC-framed stdio wire protocol for
    process-isolated workers, plus :class:`TransportChaos` wire-fault
    injection
  - :mod:`repro.fleet.worker_main` — the ``python -m repro.fleet.worker_main``
    subprocess entrypoint driven by :class:`SubprocessWorker`
"""

from .telemetry import (PodCountChange, PodFailure, StageDrift, StageTimings,
                        Trace, event_from_wire, event_to_wire,
                        gen_burst_trace, make_fleet)
from .signatures import (Signature, canonicalize, remap_alloc, signature,
                         span_bucket)
from .journal import Journal, JournalError
from .transport import (FrameError, FrameReader, TransportChaos, encode_frame)
from .supervision import (InlineWorker, SubprocessWorker, Supervisor,
                          ThreadWorker, WorkerCrash, WorkerFailed,
                          WorkerSolveError, WorkerTimeout,
                          subprocess_supervisor)
from .service import InstanceState, ReplanService
from .metrics import FleetMetrics
from .chaos import ChaosSpec, SimulatedCrash, crash_restart_run, inject_chaos

__all__ = [
    "StageTimings", "StageDrift", "PodCountChange", "PodFailure",
    "Trace", "gen_burst_trace", "make_fleet",
    "event_to_wire", "event_from_wire",
    "Signature", "signature", "canonicalize", "remap_alloc", "span_bucket",
    "Journal", "JournalError",
    "Supervisor", "InlineWorker", "ThreadWorker", "SubprocessWorker",
    "subprocess_supervisor",
    "WorkerFailed", "WorkerTimeout", "WorkerCrash", "WorkerSolveError",
    "FrameError", "FrameReader", "TransportChaos", "encode_frame",
    "ReplanService", "InstanceState",
    "FleetMetrics",
    "ChaosSpec", "inject_chaos", "SimulatedCrash", "crash_restart_run",
]
