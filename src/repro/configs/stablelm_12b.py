"""stablelm-12b [dense]: GQA.  [hf:stabilityai/stablelm-2-1_6b; hf]"""

from ..models.common import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        arch_id="stablelm-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
        d_ff=13824, vocab_size=100352,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        arch_id="stablelm-12b-smoke", family="dense",
        n_layers=3, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab_size=512,
    )
