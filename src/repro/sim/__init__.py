"""Paper simulation study (Section 5): random instance generators E1-E4,
experiment runner, failure thresholds."""

from .generators import EXPERIMENTS, gen_instance
from .experiments import (run_experiment, failure_thresholds, trajectory,
                          summarize_experiment)

__all__ = ["EXPERIMENTS", "gen_instance", "run_experiment", "failure_thresholds",
           "trajectory", "summarize_experiment"]
